"""Vision serving subsystem: stage compiler correctness, pipelined
bit-exactness vs the monolithic integer runner, bucket admission edge cases,
deadline handling, deterministic fake-clock stress tests (EDF under expiry,
padding tails, bounded queue, NaN-safe stats, multi-model routing/fairness,
sharded multi-replica serving), and a queue-drain throughput smoke test."""
import math
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compiler as CC, cu
from repro.dist.sharding import data_mesh
from repro.models import efficientnet as effn, mobilenet_v2 as mnv2
from repro.models.layers import make_calibrated_qnet
from repro.serve.vision import (
    AdmissionError,
    MultiModelEngine,
    PipelinedExecutor,
    VisionEngine,
    compile_stages,
)

HW = 32


class FakeClock:
    """Deterministic injectable time source: every read ticks by `step`
    (so completion order is observable in latencies), plus manual
    `advance` for deadline scenarios — no wall-clock sleeps anywhere."""

    def __init__(self, t0: float = 0.0, step: float = 0.0):
        self.t = t0
        self.step = step

    def __call__(self) -> float:
        self.t += self.step
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def _make_qnet(net, seed=0):
    return make_calibrated_qnet(net, seed=seed)


@pytest.fixture(scope="module")
def mnv2_qnet():
    return _make_qnet(mnv2.build(alpha=0.35, input_hw=HW, num_classes=10))


@pytest.fixture(scope="module")
def effnet_qnet():
    return _make_qnet(effn.build_compact(input_hw=HW, num_classes=10))


def _images(n, seed=7):
    return np.asarray(jax.random.uniform(
        jax.random.PRNGKey(seed), (n, HW, HW, 3), minval=-1, maxval=1))


# ---------------------------------------------------------------------------
# stage compiler
# ---------------------------------------------------------------------------


def test_stage_signatures_mobilenet(mnv2_qnet):
    plan = CC.compile_net(mnv2_qnet.spec)
    sigs = plan.stage_signatures()
    assert [s.cu for s in sigs] == [CC.HEAD, CC.BODY, CC.TAIL, CC.CLASSIFIER]
    head, body, tail, clf = sigs
    assert head.in_hw == HW and head.in_ch == 3
    # stage boundaries chain: out of one == in of the next
    assert (head.out_hw, head.out_ch) == (body.in_hw, body.in_ch)
    assert (body.out_hw, body.out_ch) == (tail.in_hw, tail.in_ch)
    assert tail.out_hw is None  # spatially collapsed by the global pool
    assert clf.out_ch == 10
    assert body.invocations == 16  # the paper's 16 Body CU invocations


def test_stage_quantizer_handoff_is_static(mnv2_qnet):
    stages = compile_stages(mnv2_qnet)
    # (scale, zp) contract chains across stages and matches the data-free
    # propagation from QNet metadata
    s, z = cu.input_qparams(mnv2_qnet)
    for st in stages:
        assert (st.spec.in_scale, st.spec.in_zp) == (s, z)
        s, z = cu.propagate_qparams(st.spec.blocks, mnv2_qnet, s, z)
        assert (st.spec.out_scale, st.spec.out_zp) == (s, z)


def test_run_blocks_matches_run_qnet(mnv2_qnet):
    x = jnp.asarray(_images(2))
    in_s, in_z = cu.input_qparams(mnv2_qnet)
    y = cu.quantize_input(x, in_s, in_z, 8)
    y, s, z = cu.run_blocks(y, mnv2_qnet.spec.blocks, mnv2_qnet, in_s, in_z)
    got = (y.astype(jnp.float32) + z) * s
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(cu.run_qnet(mnv2_qnet, x)))


def test_fusable_irb_gate():
    from repro.core.graph import DW, PW, RELU6, NONE, BlockSpec, OpSpec
    from repro.kernels.ops import fusable_irb

    def blk(act_bits3=4):
        return BlockSpec("b", (
            OpSpec("b/expand", PW, 8, 48, 1, 1, RELU6, 4, 4),
            OpSpec("b/dw", DW, 48, 48, 3, 1, RELU6, 4, 4),
            OpSpec("b/project", PW, 48, 16, 1, 1, NONE, 4, act_bits3),
        ))

    assert fusable_irb(blk())
    # mixed act_bits: the kernel's single-qmax clip would be wrong
    assert not fusable_irb(blk(act_bits3=8))


def test_noncontiguous_schedule_rejected(mnv2_qnet):
    plan = CC.compile_net(mnv2_qnet.spec)
    # interleave: head, body, head, body... breaks role contiguity
    sched = list(plan.schedule)
    sched[1], sched[2] = sched[2], sched[1]  # head, body, head, ...
    bad = CC.CUPlan(plan.net, tuple(sched))
    with pytest.raises(ValueError, match="non-contiguous"):
        bad.stage_groups()


# ---------------------------------------------------------------------------
# pipelined execution: bit-exactness vs the monolithic runner
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("qnet_fixture", ["mnv2_qnet", "effnet_qnet"])
def test_pipelined_bit_exact_with_run_qnet(qnet_fixture, request):
    qnet = request.getfixturevalue(qnet_fixture)
    imgs = _images(5)
    eng = VisionEngine(qnet, buckets=(1, 2, 4))
    rids = [eng.submit(img) for img in imgs]
    results = eng.run()
    got = np.stack([results[r].logits for r in rids])
    ref = np.asarray(cu.run_qnet(qnet, jnp.asarray(imgs)))
    np.testing.assert_array_equal(got, ref)
    assert all(results[r].status == "ok" for r in rids)


def test_fixed_point_refuses_fused_fast_path(mnv2_qnet):
    """The fused IRB kernel has no fixed-point requant mode: forcing it on
    together with fixed_point must fail loudly, and 'auto' must fall back
    to the exact unfused path."""
    with pytest.raises(ValueError, match="fixed_point"):
        compile_stages(mnv2_qnet, fixed_point=True, body_fast_path="on")
    stages = compile_stages(mnv2_qnet, fixed_point=True,
                            body_fast_path="auto")
    assert all(not s._fast_path for s in stages)


def test_pipelined_bit_exact_fixed_point(mnv2_qnet):
    """The FPGA-faithful fixed-point requant path through the stages."""
    imgs = _images(3)
    eng = VisionEngine(mnv2_qnet, buckets=(4,), fixed_point=True)
    rids = [eng.submit(img) for img in imgs]
    results = eng.run()
    got = np.stack([results[r].logits for r in rids])
    ref = np.asarray(cu.run_qnet(mnv2_qnet, jnp.asarray(imgs),
                                 fixed_point=True))
    np.testing.assert_array_equal(got, ref)


@pytest.mark.slow
def test_pipelined_bit_exact_fused_body(mnv2_qnet):
    """Body CU through the fused Pallas IRB kernel (interpret mode on CPU)
    is still bit-exact with the monolithic reference."""
    imgs = _images(2)
    eng = VisionEngine(mnv2_qnet, buckets=(2,), body_fast_path="on",
                       interpret=not jax.default_backend() == "tpu")
    rids = [eng.submit(img) for img in imgs]
    results = eng.run()
    got = np.stack([results[r].logits for r in rids])
    ref = np.asarray(cu.run_qnet(mnv2_qnet, jnp.asarray(imgs)))
    np.testing.assert_array_equal(got, ref)


@pytest.mark.slow
@pytest.mark.parametrize("qnet_fixture", ["mnv2_qnet", "effnet_qnet"])
def test_pipelined_bit_exact_op_kernels(qnet_fixture, request):
    """Every PW/DENSE op through the Pallas pointwise-CU kernel and every DW
    op through the row-tiled depthwise kernel (interpret mode on CPU):
    full-net logits stay identical to the monolithic reference."""
    qnet = request.getfixturevalue(qnet_fixture)
    imgs = _images(2)
    eng = VisionEngine(qnet, buckets=(2,), op_kernels="on",
                       interpret=not jax.default_backend() == "tpu")
    rids = [eng.submit(img) for img in imgs]
    results = eng.run()
    got = np.stack([results[r].logits for r in rids])
    ref = np.asarray(cu.run_qnet(qnet, jnp.asarray(imgs)))
    np.testing.assert_array_equal(got, ref)


def test_pipeline_executor_ordering(mnv2_qnet):
    stages = compile_stages(mnv2_qnet)
    pipe = PipelinedExecutor(stages)
    batches = [jnp.asarray(_images(2, seed=i)) for i in range(5)]
    outs = pipe.run(batches)
    assert len(outs) == 5
    for x, y in zip(batches, outs):
        np.testing.assert_array_equal(
            np.asarray(y), np.asarray(cu.run_qnet(mnv2_qnet, x)))


def test_pipeline_stream_abandoned_mid_drain_does_not_leak(mnv2_qnet):
    """Breaking out of stream() mid-drain must drop the in-flight batches:
    a later drain on the same executor must not replay stale tags."""
    stages = compile_stages(mnv2_qnet)
    pipe = PipelinedExecutor(stages)
    batches = [jnp.asarray(_images(2, seed=i)) for i in range(3)]
    for _ in pipe.stream(enumerate(batches)):
        break  # abandon with batches still in flight
    assert not pipe.busy
    outs = pipe.run(batches)  # fresh drain: exactly these 3, nothing stale
    assert len(outs) == 3
    np.testing.assert_array_equal(
        np.asarray(outs[0]),
        np.asarray(cu.run_qnet(mnv2_qnet, batches[0])))


# ---------------------------------------------------------------------------
# bucket admission edge cases
# ---------------------------------------------------------------------------


def test_odd_tail_is_bucket_padded(mnv2_qnet):
    eng = VisionEngine(mnv2_qnet, buckets=(2, 4))
    imgs = _images(7)  # -> 4 + 4(pad 1) under EDF draining
    rids = [eng.submit(img) for img in imgs]
    results = eng.run()
    stats = eng.stats()
    assert stats.n_ok == 7
    assert stats.micro_batches == 2
    assert stats.pad_fraction == pytest.approx(1 / 8)
    ref = np.asarray(cu.run_qnet(mnv2_qnet, jnp.asarray(imgs)))
    got = np.stack([results[r].logits for r in rids])
    np.testing.assert_array_equal(got, ref)  # pad rows never leak


def test_single_request_uses_smallest_bucket(mnv2_qnet):
    eng = VisionEngine(mnv2_qnet, buckets=(1, 2, 4))
    eng.submit(_images(1)[0])
    eng.run()
    assert eng.stats().pad_fraction == 0.0


def test_mixed_shapes_rejected(mnv2_qnet):
    eng = VisionEngine(mnv2_qnet, buckets=(2,))
    eng.submit(_images(1)[0])
    with pytest.raises(AdmissionError, match="shape"):
        eng.submit(np.zeros((HW // 2, HW // 2, 3), np.float32))
    with pytest.raises(AdmissionError, match="shape"):
        eng.submit(np.zeros((HW, HW, 4), np.float32))
    with pytest.raises(AdmissionError, match="dtype"):
        eng.submit(np.zeros((HW, HW, 3), np.uint8))
    assert eng.pending() == 1  # rejected work never queued


def test_queue_bound(mnv2_qnet):
    eng = VisionEngine(mnv2_qnet, buckets=(2,), max_queue=2)
    img = _images(1)[0]
    eng.submit(img)
    eng.submit(img)
    with pytest.raises(AdmissionError, match="queue full"):
        eng.submit(img)


def test_expired_deadline_dropped(mnv2_qnet):
    eng = VisionEngine(mnv2_qnet, buckets=(2,))
    img = _images(1)[0]
    past = time.perf_counter() - 10.0
    dead = eng.submit(img, deadline_s=past)
    live = eng.submit(img)
    results = eng.run()
    assert results[dead].status == "expired"
    assert results[dead].logits is None
    assert results[live].status == "ok"
    stats = eng.stats()
    assert stats.n_expired == 1 and stats.n_ok == 1


def test_edf_orders_batches(mnv2_qnet):
    """Tighter deadlines are served in earlier micro-batches."""
    eng = VisionEngine(mnv2_qnet, buckets=(2,))
    img = _images(1)[0]
    now = time.perf_counter()
    eng.submit(img, deadline_s=now + 1000)  # loose deadline
    tight = eng.submit(img, deadline_s=now + 100)
    nodeadline = eng.submit(img)
    results = eng.run()
    # tight + loose share the first bucket-2 batch; no-deadline rides last
    assert results[tight].latency_s <= results[nodeadline].latency_s
    assert all(r.status == "ok" for r in results.values())


# ---------------------------------------------------------------------------
# deterministic fake-clock stress tests
# ---------------------------------------------------------------------------


def test_fake_clock_expiry_is_deterministic(mnv2_qnet):
    """Deadline expiry is decided against the injected clock at batch-form
    time — no sleeps, no wall-clock racing."""
    clock = FakeClock(t0=100.0)
    eng = VisionEngine(mnv2_qnet, buckets=(2,), clock=clock)
    img = _images(1)[0]
    dead = eng.submit(img, deadline_s=50.0)   # already past the fake now
    live = eng.submit(img, deadline_s=200.0)
    later = eng.submit(img, deadline_s=101.0)
    clock.advance(5.0)  # 105.0: 'later' expires before the drain
    results = eng.run()
    assert results[dead].status == "expired"
    assert results[later].status == "expired"
    assert results[live].status == "ok"
    stats = eng.stats()
    assert (stats.n_ok, stats.n_expired) == (1, 2)
    assert stats.micro_batches == 1  # expired requests burn no CU work


def test_fake_clock_edf_dispatch_order(mnv2_qnet):
    """Tighter deadlines land in earlier micro-batches: with a ticking
    clock, completion times (latencies from a common arrival) are ordered
    exactly by deadline tightness, batch by batch."""
    clock = FakeClock(t0=0.0, step=1e-4)
    eng = VisionEngine(mnv2_qnet, buckets=(2,), clock=clock)
    img = _images(1)[0]
    # submit in scrambled order; all share arrival now=0
    d = {eng.submit(img, deadline_s=dl, now=0.0): dl
         for dl in (300.0, 110.0, 150.0, 120.0)}
    results = eng.run()
    assert all(r.status == "ok" for r in results.values())
    # sort rids by their deadline; EDF packs [110,120] then [150,300]
    by_deadline = sorted(d, key=lambda r: d[r])
    lat = [results[r].latency_s for r in by_deadline]
    assert lat[0] == lat[1] < lat[2] == lat[3], lat


def test_fake_clock_padding_tail(mnv2_qnet):
    """5 requests over (2, 4) buckets: one full 4-bucket + a padded 2-bucket
    (deterministic — the fake clock never expires anything mid-drain)."""
    clock = FakeClock(t0=0.0)
    eng = VisionEngine(mnv2_qnet, buckets=(2, 4), clock=clock)
    for img in _images(5):
        eng.submit(img)
    results = eng.run()
    stats = eng.stats()
    assert stats.n_ok == 5
    assert stats.micro_batches == 2
    assert stats.pad_fraction == pytest.approx(1 / 6)
    assert all(r.status == "ok" for r in results.values())


def test_bounded_queue_frees_capacity_after_drain(mnv2_qnet):
    clock = FakeClock()
    eng = VisionEngine(mnv2_qnet, buckets=(2,), max_queue=2, clock=clock)
    img = _images(1)[0]
    eng.submit(img)
    eng.submit(img)
    with pytest.raises(AdmissionError, match="queue full"):
        eng.submit(img)
    eng.run()
    assert eng.pending() == 0
    eng.submit(img)  # drained queue admits again


def test_all_expired_stats_nan_safe(mnv2_qnet):
    """Regression: when every request expires there are zero completions —
    stats() must report NaN percentiles (not a misleading 0.0 or a
    divide-by-zero) and keep every ratio finite."""
    clock = FakeClock(t0=1000.0)
    eng = VisionEngine(mnv2_qnet, buckets=(2,), clock=clock)
    for img in _images(3):
        eng.submit(img, deadline_s=1.0)  # all long past
    results = eng.run()
    assert all(r.status == "expired" for r in results.values())
    stats = eng.stats()
    assert stats.n_ok == 0 and stats.n_expired == 3
    assert math.isnan(stats.latency_p50_s)
    assert math.isnan(stats.latency_p95_s)
    assert stats.fps == 0.0
    assert stats.pad_fraction == 0.0
    assert stats.micro_batches == 0
    stats.as_dict()  # stays serializable


# ---------------------------------------------------------------------------
# multi-model routing
# ---------------------------------------------------------------------------


@pytest.fixture()
def router(mnv2_qnet, effnet_qnet):
    clock = FakeClock(t0=0.0, step=1e-4)
    return MultiModelEngine({
        "mnv2": VisionEngine(mnv2_qnet, buckets=(2,), clock=clock),
        "effnet": VisionEngine(effnet_qnet, buckets=(2,), clock=clock),
    }, clock=clock), clock


def test_multi_model_bit_exact_and_tagged(router, mnv2_qnet, effnet_qnet):
    mm, clock = router
    imgs = _images(4)
    handles = [mm.submit("mnv2" if i % 2 == 0 else "effnet", img, now=0.0)
               for i, img in enumerate(imgs)]
    results = mm.run()
    assert all(results[h].status == "ok" for h in handles)
    refs = {"mnv2": np.asarray(cu.run_qnet(mnv2_qnet, jnp.asarray(imgs))),
            "effnet": np.asarray(cu.run_qnet(effnet_qnet, jnp.asarray(imgs)))}
    for i, h in enumerate(handles):
        np.testing.assert_array_equal(results[h].logits, refs[h[0]][i])
    stats = mm.stats()
    assert set(stats) == {"mnv2", "effnet"}
    assert stats["mnv2"].n_ok == stats["effnet"].n_ok == 2


def test_multi_model_unknown_model_rejected(router):
    mm, _ = router
    with pytest.raises(AdmissionError, match="unknown model"):
        mm.submit("resnet", _images(1)[0])


def test_multi_model_mixed_clocks_rejected(mnv2_qnet, effnet_qnet):
    """Wall time, latencies, and deadlines must share ONE time source: the
    router refuses engines holding different clocks unless an explicit
    clock= unifies them (which is propagated down)."""
    with pytest.raises(ValueError, match="clock"):
        MultiModelEngine({
            "a": VisionEngine(mnv2_qnet, buckets=(2,), clock=FakeClock()),
            "b": VisionEngine(effnet_qnet, buckets=(2,), clock=FakeClock()),
        })
    shared = FakeClock()
    mm = MultiModelEngine({
        "a": VisionEngine(mnv2_qnet, buckets=(2,), clock=FakeClock()),
        "b": VisionEngine(effnet_qnet, buckets=(2,), clock=FakeClock()),
    }, clock=shared)
    assert all(e._clock is shared for e in mm.engines.values())


def test_multi_model_fairness_round_robin(router):
    """Deadline-less load from two models interleaves one micro-batch per
    model per scheduler round — neither model starves the other."""
    mm, _ = router
    for i, img in enumerate(_images(8)):
        mm.submit("mnv2" if i < 4 else "effnet", img, now=0.0)
    results = mm.run()
    assert all(r.status == "ok" for r in results.values())
    order = [m for m, _ in mm.dispatch_log]
    assert sorted(order) == ["effnet", "effnet", "mnv2", "mnv2"]
    # strict alternation: a model never dispatches twice in a row
    assert all(a != b for a, b in zip(order, order[1:])), order


def test_multi_model_edf_prioritizes_tight_deadlines(router):
    """The model holding the tightest next deadline dispatches first into
    the shared device stream, regardless of name order."""
    mm, clock = router
    img = _images(1)[0]
    # effnet sorts first by name — give mnv2 the tighter deadlines to show
    # EDF (not name order) decides
    for _ in range(2):
        mm.submit("effnet", img, deadline_s=1e6, now=0.0)
        mm.submit("mnv2", img, deadline_s=10.0, now=0.0)
    results = mm.run()
    assert all(r.status == "ok" for r in results.values())
    assert mm.dispatch_log[0][0] == "mnv2", mm.dispatch_log


# ---------------------------------------------------------------------------
# sharded multi-replica serving
# ---------------------------------------------------------------------------


def test_sharded_single_replica_mesh_is_bit_exact(mnv2_qnet):
    """mesh over 1 device: the degenerate sharded path must match the
    monolithic reference exactly (and keep every bucket unchanged)."""
    imgs = _images(4)
    eng = VisionEngine(mnv2_qnet, buckets=(1, 2, 4), mesh=data_mesh(1))
    assert eng.buckets == (1, 2, 4) and eng.replicas == 1
    rids = [eng.submit(img) for img in imgs]
    results = eng.run()
    got = np.stack([results[r].logits for r in rids])
    np.testing.assert_array_equal(
        got, np.asarray(cu.run_qnet(mnv2_qnet, jnp.asarray(imgs))))


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >=2 devices (set "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
def test_sharded_multi_replica_bit_exact(mnv2_qnet):
    """Micro-batches sharded across the 'data' mesh produce logits
    bit-identical to the single-device engine, and every requested bucket
    is rounded up to a replica multiple at construction."""
    n = 2 * (len(jax.devices()) // 2)
    mesh = data_mesh(n)
    eng = VisionEngine(mnv2_qnet, buckets=(1, 2, 4, n, 2 * n), mesh=mesh)
    assert all(b % n == 0 for b in eng.buckets)
    assert eng.replicas == n
    imgs = _images(2 * n)
    rids = [eng.submit(img) for img in imgs]
    results = eng.run()
    got = np.stack([results[r].logits for r in rids])
    np.testing.assert_array_equal(
        got, np.asarray(cu.run_qnet(mnv2_qnet, jnp.asarray(imgs))))
    assert eng.stats().replicas == n


def test_sharded_buckets_round_up_to_replica_multiples(mnv2_qnet):
    if len(jax.devices()) < 2:
        with pytest.raises(ValueError, match="replicas"):
            data_mesh(2)
        return
    eng = VisionEngine(mnv2_qnet, buckets=(1, 3, 4), mesh=data_mesh(2))
    assert eng.buckets == (2, 4)  # 1 -> 2, 3 -> 4 (merged), 4 stays
    img = _images(1)[0]
    rid = eng.submit(img)
    res = eng.run()
    np.testing.assert_array_equal(
        res[rid].logits,
        np.asarray(cu.run_qnet(mnv2_qnet, jnp.asarray(img[None])))[0])


# ---------------------------------------------------------------------------
# queue-drain throughput smoke test
# ---------------------------------------------------------------------------


def test_queue_drain_throughput_smoke(mnv2_qnet):
    eng = VisionEngine(mnv2_qnet, buckets=(4,))
    eng.warmup()
    imgs = _images(16)
    rids = [eng.submit(img) for img in imgs]
    results = eng.run()
    stats = eng.stats()
    assert sorted(results) == sorted(rids)
    assert stats.n_ok == 16
    assert stats.fps > 0
    assert stats.micro_batches == 4
    # every CU stage invoked exactly once per micro-batch (warmup excluded)
    assert all(v == stats.micro_batches
               for v in stats.stage_invocations.values())
    assert stats.macs_per_image == mnv2_qnet.spec.count_macs()
    assert stats.energy_j_per_image > 0
    assert stats.watts >= stats.fps * stats.energy_j_per_image
    assert stats.fps_per_watt > 0
    d = stats.as_dict()
    assert {"fps", "latency_p50_s", "fps_per_watt", "watts",
            "power_source", "energy_tuned_fraction"} <= set(d)


# ---------------------------------------------------------------------------
# power-capped dispatch (docs/energy.md): deterministic fake-clock stress
# ---------------------------------------------------------------------------


def _fat_energy(j_per_image: float, idle_w: float = 0.0):
    """Synthetic EnergyReport with an exact J/image — the governor tests
    need batch energies that dominate the budget, not mnv2's real uJ."""
    from repro.energy import EnergyReport, OpEnergy, PowerModel

    op = OpEnergy(name="fat", cu="body", kind="pw", key="", us=1.0,
                  source="analytic", macs=1, bytes_moved=1,
                  compute_j=j_per_image, memory_j=0.0)
    return EnergyReport(net="fake", backend="cpu",
                        power=PowerModel(busy_w=max(10.0, idle_w + 1.0),
                                         idle_w=idle_w, source="test"),
                        ops=(op,))


def test_power_cap_stays_under_budget_zero_high_slo_drops(mnv2_qnet):
    """The acceptance stress: 1 J/image, 10 W budget over a 1 s window ->
    at most 2 bucket-4 batches per window. The governor must (a) keep the
    modeled watts under budget at every dispatch point, (b) shed ONLY the
    shed class (slo <= 0), (c) serve every slo-1 request eventually —
    zero drops above the shed class."""
    clock = FakeClock(step=1e-4)
    eng = VisionEngine(mnv2_qnet, buckets=(4,), clock=clock,
                       energy=_fat_energy(1.0), power_budget_w=10.0,
                       power_window_s=1.0, shed_slo=0)
    imgs = _images(12)
    rids = {eng.submit(img, slo=i % 2): i % 2
            for i, img in enumerate(imgs)}
    results = {}
    for _ in range(8):  # drain over advancing windows
        results.update(eng.run())
        assert eng._governor.watts(clock.t) <= 10.0 + 1e-9
        if not eng.pending():
            break
        clock.advance(0.5)
    assert not eng.pending()
    by_status = {}
    for rid, slo in rids.items():
        by_status.setdefault(results[rid].status, []).append(slo)
    # every shed request was sheddable; every slo-1 request came back ok
    assert set(by_status.get("shed", [])) <= {0}
    assert all(results[rid].status == "ok"
               for rid, slo in rids.items() if slo == 1)
    stats = eng.stats()
    assert stats.n_shed == len(by_status.get("shed", []))
    assert stats.n_deferred > 0  # the cap actually bit
    assert stats.power_budget_w == 10.0
    # shed results carry no logits; ok results are bit-exact
    for rid, slo in rids.items():
        if results[rid].status == "ok":
            ref = np.asarray(cu.run_qnet(
                mnv2_qnet, jnp.asarray(imgs[list(rids).index(rid)][None])))
            np.testing.assert_array_equal(results[rid].logits, ref[0])
        else:
            assert results[rid].logits is None


def test_power_cap_generous_budget_never_sheds(mnv2_qnet):
    clock = FakeClock(step=1e-4)
    eng = VisionEngine(mnv2_qnet, buckets=(4,), clock=clock,
                       energy=_fat_energy(1e-3), power_budget_w=100.0)
    rids = [eng.submit(img, slo=0) for img in _images(8)]
    results = eng.run()
    assert all(results[r].status == "ok" for r in rids)
    stats = eng.stats()
    assert stats.n_shed == 0 and stats.n_deferred == 0


def test_power_cap_deferred_requests_keep_deadlines(mnv2_qnet):
    """Deferral is not terminal and preserves EDF ordering: a deferred
    request with a live deadline is served on the next window; one whose
    deadline passes while deferred expires (not sheds)."""
    clock = FakeClock(step=1e-4)
    eng = VisionEngine(mnv2_qnet, buckets=(2,), clock=clock,
                       energy=_fat_energy(1.0), power_budget_w=6.0,
                       power_window_s=1.0, shed_slo=-1)  # nothing sheddable
    imgs = _images(6)
    now = clock.t
    r_live = eng.submit(imgs[0], slo=1, deadline_s=now + 100.0)
    r_tight = eng.submit(imgs[1], slo=1, deadline_s=now + 0.3)
    rest = [eng.submit(img, slo=1) for img in imgs[2:]]
    results = dict(eng.run())  # EDF serves r_tight first; budget defers tail
    for _ in range(6):
        if not eng.pending():
            break
        clock.advance(0.6)
        results.update(eng.run())
    assert results[r_tight].status == "ok"  # tight deadline went first
    assert results[r_live].status == "ok"
    # everything else either completed or expired while deferred — but
    # nothing was shed (shed_slo=-1) and nothing vanished
    assert set(results) == {r_live, r_tight, *rest}
    assert all(results[r].status in ("ok", "expired") for r in rest)
    assert eng.stats().n_shed == 0


def test_power_budget_must_clear_idle_floor(mnv2_qnet):
    with pytest.raises(ValueError):
        VisionEngine(mnv2_qnet, buckets=(2,),
                     energy=_fat_energy(1.0, idle_w=5.0),
                     power_budget_w=4.0)  # budget below idle draw


def test_multi_model_shared_power_budget(mnv2_qnet, effnet_qnet):
    """One governor spans the fleet: both models' dispatches debit the
    same rolling window, and the shared watt estimate stays capped."""
    clock = FakeClock(step=1e-4)
    engines = {
        "m": VisionEngine(mnv2_qnet, buckets=(2,), clock=clock,
                          energy=_fat_energy(1.0), name="m"),
        "e": VisionEngine(effnet_qnet, buckets=(2,), clock=clock,
                          energy=_fat_energy(1.0), name="e"),
    }
    router = MultiModelEngine(engines, power_budget_w=5.0)
    assert engines["m"]._governor is router.governor
    assert engines["e"]._governor is router.governor
    handles = [router.submit("m" if i % 2 == 0 else "e", img, slo=1)
               for i, img in enumerate(_images(8))]
    results = dict(router.run())
    for _ in range(8):
        if not any(e.pending() for e in engines.values()):
            break
        assert router.governor.watts(clock.t) <= 5.0 + 1e-9
        clock.advance(1.0)
        results.update(router.run())
    assert all(results[h].status == "ok" for h in handles)
    assert router.governor.total_j > 0


def test_multi_model_refuses_double_governor(mnv2_qnet, effnet_qnet):
    clock = FakeClock()
    owned = VisionEngine(mnv2_qnet, buckets=(2,), clock=clock,
                         energy=_fat_energy(1.0), power_budget_w=10.0)
    other = VisionEngine(effnet_qnet, buckets=(2,), clock=clock,
                         energy=_fat_energy(1.0))
    with pytest.raises(ValueError):
        MultiModelEngine({"a": owned, "b": other}, power_budget_w=5.0)
