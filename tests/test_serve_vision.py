"""Vision serving subsystem: stage compiler correctness, pipelined
bit-exactness vs the monolithic integer runner, bucket admission edge cases,
deadline handling, and a queue-drain throughput smoke test."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compiler as CC, cu, qnet as Q
from repro.core.calibrate import calibrate
from repro.core.quant import QuantConfig
from repro.models import efficientnet as effn, layers, mobilenet_v2 as mnv2
from repro.serve.vision import (
    AdmissionError,
    PipelinedExecutor,
    VisionEngine,
    compile_stages,
)

HW = 32


def _make_qnet(net, seed=0):
    params = layers.init_params(jax.random.PRNGKey(seed), net)

    def apply_fn(p, b):
        return layers.forward(p, b, net, capture=True)[1]

    cal = [jax.random.uniform(jax.random.PRNGKey(i), (2, HW, HW, 3),
                              minval=-1, maxval=1) for i in range(2)]
    obs = calibrate(apply_fn, params, cal, QuantConfig(4, False, None))
    return Q.quantize_net(params, net, obs)


@pytest.fixture(scope="module")
def mnv2_qnet():
    return _make_qnet(mnv2.build(alpha=0.35, input_hw=HW, num_classes=10))


@pytest.fixture(scope="module")
def effnet_qnet():
    return _make_qnet(effn.build_compact(input_hw=HW, num_classes=10))


def _images(n, seed=7):
    return np.asarray(jax.random.uniform(
        jax.random.PRNGKey(seed), (n, HW, HW, 3), minval=-1, maxval=1))


# ---------------------------------------------------------------------------
# stage compiler
# ---------------------------------------------------------------------------


def test_stage_signatures_mobilenet(mnv2_qnet):
    plan = CC.compile_net(mnv2_qnet.spec)
    sigs = plan.stage_signatures()
    assert [s.cu for s in sigs] == [CC.HEAD, CC.BODY, CC.TAIL, CC.CLASSIFIER]
    head, body, tail, clf = sigs
    assert head.in_hw == HW and head.in_ch == 3
    # stage boundaries chain: out of one == in of the next
    assert (head.out_hw, head.out_ch) == (body.in_hw, body.in_ch)
    assert (body.out_hw, body.out_ch) == (tail.in_hw, tail.in_ch)
    assert tail.out_hw is None  # spatially collapsed by the global pool
    assert clf.out_ch == 10
    assert body.invocations == 16  # the paper's 16 Body CU invocations


def test_stage_quantizer_handoff_is_static(mnv2_qnet):
    stages = compile_stages(mnv2_qnet)
    # (scale, zp) contract chains across stages and matches the data-free
    # propagation from QNet metadata
    s, z = cu.input_qparams(mnv2_qnet)
    for st in stages:
        assert (st.spec.in_scale, st.spec.in_zp) == (s, z)
        s, z = cu.propagate_qparams(st.spec.blocks, mnv2_qnet, s, z)
        assert (st.spec.out_scale, st.spec.out_zp) == (s, z)


def test_run_blocks_matches_run_qnet(mnv2_qnet):
    x = jnp.asarray(_images(2))
    in_s, in_z = cu.input_qparams(mnv2_qnet)
    y = cu.quantize_input(x, in_s, in_z, 8)
    y, s, z = cu.run_blocks(y, mnv2_qnet.spec.blocks, mnv2_qnet, in_s, in_z)
    got = (y.astype(jnp.float32) + z) * s
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(cu.run_qnet(mnv2_qnet, x)))


def test_fusable_irb_gate():
    from repro.core.graph import DW, PW, RELU6, NONE, BlockSpec, OpSpec
    from repro.kernels.ops import fusable_irb

    def blk(act_bits3=4):
        return BlockSpec("b", (
            OpSpec("b/expand", PW, 8, 48, 1, 1, RELU6, 4, 4),
            OpSpec("b/dw", DW, 48, 48, 3, 1, RELU6, 4, 4),
            OpSpec("b/project", PW, 48, 16, 1, 1, NONE, 4, act_bits3),
        ))

    assert fusable_irb(blk())
    # mixed act_bits: the kernel's single-qmax clip would be wrong
    assert not fusable_irb(blk(act_bits3=8))


def test_noncontiguous_schedule_rejected(mnv2_qnet):
    plan = CC.compile_net(mnv2_qnet.spec)
    # interleave: head, body, head, body... breaks role contiguity
    sched = list(plan.schedule)
    sched[1], sched[2] = sched[2], sched[1]  # head, body, head, ...
    bad = CC.CUPlan(plan.net, tuple(sched))
    with pytest.raises(ValueError, match="non-contiguous"):
        bad.stage_groups()


# ---------------------------------------------------------------------------
# pipelined execution: bit-exactness vs the monolithic runner
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("qnet_fixture", ["mnv2_qnet", "effnet_qnet"])
def test_pipelined_bit_exact_with_run_qnet(qnet_fixture, request):
    qnet = request.getfixturevalue(qnet_fixture)
    imgs = _images(5)
    eng = VisionEngine(qnet, buckets=(1, 2, 4))
    rids = [eng.submit(img) for img in imgs]
    results = eng.run()
    got = np.stack([results[r].logits for r in rids])
    ref = np.asarray(cu.run_qnet(qnet, jnp.asarray(imgs)))
    np.testing.assert_array_equal(got, ref)
    assert all(results[r].status == "ok" for r in rids)


def test_fixed_point_refuses_fused_fast_path(mnv2_qnet):
    """The fused IRB kernel has no fixed-point requant mode: forcing it on
    together with fixed_point must fail loudly, and 'auto' must fall back
    to the exact unfused path."""
    with pytest.raises(ValueError, match="fixed_point"):
        compile_stages(mnv2_qnet, fixed_point=True, body_fast_path="on")
    stages = compile_stages(mnv2_qnet, fixed_point=True,
                            body_fast_path="auto")
    assert all(not s._fast_path for s in stages)


def test_pipelined_bit_exact_fixed_point(mnv2_qnet):
    """The FPGA-faithful fixed-point requant path through the stages."""
    imgs = _images(3)
    eng = VisionEngine(mnv2_qnet, buckets=(4,), fixed_point=True)
    rids = [eng.submit(img) for img in imgs]
    results = eng.run()
    got = np.stack([results[r].logits for r in rids])
    ref = np.asarray(cu.run_qnet(mnv2_qnet, jnp.asarray(imgs),
                                 fixed_point=True))
    np.testing.assert_array_equal(got, ref)


@pytest.mark.slow
def test_pipelined_bit_exact_fused_body(mnv2_qnet):
    """Body CU through the fused Pallas IRB kernel (interpret mode on CPU)
    is still bit-exact with the monolithic reference."""
    imgs = _images(2)
    eng = VisionEngine(mnv2_qnet, buckets=(2,), body_fast_path="on",
                       interpret=not jax.default_backend() == "tpu")
    rids = [eng.submit(img) for img in imgs]
    results = eng.run()
    got = np.stack([results[r].logits for r in rids])
    ref = np.asarray(cu.run_qnet(mnv2_qnet, jnp.asarray(imgs)))
    np.testing.assert_array_equal(got, ref)


@pytest.mark.slow
@pytest.mark.parametrize("qnet_fixture", ["mnv2_qnet", "effnet_qnet"])
def test_pipelined_bit_exact_op_kernels(qnet_fixture, request):
    """Every PW/DENSE op through the Pallas pointwise-CU kernel and every DW
    op through the row-tiled depthwise kernel (interpret mode on CPU):
    full-net logits stay identical to the monolithic reference."""
    qnet = request.getfixturevalue(qnet_fixture)
    imgs = _images(2)
    eng = VisionEngine(qnet, buckets=(2,), op_kernels="on",
                       interpret=not jax.default_backend() == "tpu")
    rids = [eng.submit(img) for img in imgs]
    results = eng.run()
    got = np.stack([results[r].logits for r in rids])
    ref = np.asarray(cu.run_qnet(qnet, jnp.asarray(imgs)))
    np.testing.assert_array_equal(got, ref)


def test_pipeline_executor_ordering(mnv2_qnet):
    stages = compile_stages(mnv2_qnet)
    pipe = PipelinedExecutor(stages)
    batches = [jnp.asarray(_images(2, seed=i)) for i in range(5)]
    outs = pipe.run(batches)
    assert len(outs) == 5
    for x, y in zip(batches, outs):
        np.testing.assert_array_equal(
            np.asarray(y), np.asarray(cu.run_qnet(mnv2_qnet, x)))


# ---------------------------------------------------------------------------
# bucket admission edge cases
# ---------------------------------------------------------------------------


def test_odd_tail_is_bucket_padded(mnv2_qnet):
    eng = VisionEngine(mnv2_qnet, buckets=(2, 4))
    imgs = _images(7)  # -> 4 + 4(pad 1) under EDF draining
    rids = [eng.submit(img) for img in imgs]
    results = eng.run()
    stats = eng.stats()
    assert stats.n_ok == 7
    assert stats.micro_batches == 2
    assert stats.pad_fraction == pytest.approx(1 / 8)
    ref = np.asarray(cu.run_qnet(mnv2_qnet, jnp.asarray(imgs)))
    got = np.stack([results[r].logits for r in rids])
    np.testing.assert_array_equal(got, ref)  # pad rows never leak


def test_single_request_uses_smallest_bucket(mnv2_qnet):
    eng = VisionEngine(mnv2_qnet, buckets=(1, 2, 4))
    eng.submit(_images(1)[0])
    eng.run()
    assert eng.stats().pad_fraction == 0.0


def test_mixed_shapes_rejected(mnv2_qnet):
    eng = VisionEngine(mnv2_qnet, buckets=(2,))
    eng.submit(_images(1)[0])
    with pytest.raises(AdmissionError, match="shape"):
        eng.submit(np.zeros((HW // 2, HW // 2, 3), np.float32))
    with pytest.raises(AdmissionError, match="shape"):
        eng.submit(np.zeros((HW, HW, 4), np.float32))
    with pytest.raises(AdmissionError, match="dtype"):
        eng.submit(np.zeros((HW, HW, 3), np.uint8))
    assert eng.pending() == 1  # rejected work never queued


def test_queue_bound(mnv2_qnet):
    eng = VisionEngine(mnv2_qnet, buckets=(2,), max_queue=2)
    img = _images(1)[0]
    eng.submit(img)
    eng.submit(img)
    with pytest.raises(AdmissionError, match="queue full"):
        eng.submit(img)


def test_expired_deadline_dropped(mnv2_qnet):
    eng = VisionEngine(mnv2_qnet, buckets=(2,))
    img = _images(1)[0]
    past = time.perf_counter() - 10.0
    dead = eng.submit(img, deadline_s=past)
    live = eng.submit(img)
    results = eng.run()
    assert results[dead].status == "expired"
    assert results[dead].logits is None
    assert results[live].status == "ok"
    stats = eng.stats()
    assert stats.n_expired == 1 and stats.n_ok == 1


def test_edf_orders_batches(mnv2_qnet):
    """Tighter deadlines are served in earlier micro-batches."""
    eng = VisionEngine(mnv2_qnet, buckets=(2,))
    img = _images(1)[0]
    now = time.perf_counter()
    loose = eng.submit(img, deadline_s=now + 1000)
    tight = eng.submit(img, deadline_s=now + 100)
    nodeadline = eng.submit(img)
    results = eng.run()
    # tight + loose share the first bucket-2 batch; no-deadline rides last
    assert results[tight].latency_s <= results[nodeadline].latency_s
    assert all(r.status == "ok" for r in results.values())


# ---------------------------------------------------------------------------
# queue-drain throughput smoke test
# ---------------------------------------------------------------------------


def test_queue_drain_throughput_smoke(mnv2_qnet):
    eng = VisionEngine(mnv2_qnet, buckets=(4,))
    eng.warmup()
    imgs = _images(16)
    rids = [eng.submit(img) for img in imgs]
    results = eng.run()
    stats = eng.stats()
    assert sorted(results) == sorted(rids)
    assert stats.n_ok == 16
    assert stats.fps > 0
    assert stats.micro_batches == 4
    # every CU stage invoked exactly once per micro-batch (warmup excluded)
    assert all(v == stats.micro_batches
               for v in stats.stage_invocations.values())
    assert stats.macs_per_image == mnv2_qnet.spec.count_macs()
    assert stats.energy_j_per_image_proxy > 0
    d = stats.as_dict()
    assert {"fps", "latency_p50_s", "fps_per_watt_proxy"} <= set(d)
