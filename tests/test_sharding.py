"""Logical-axis sharding rules + shape fitting + mesh plumbing."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as SH
from repro.launch.mesh import make_host_mesh


def test_logical_rules_single_pod():
    mesh = make_host_mesh()
    spec = SH.logical_to_spec(("batch", None, "heads"), mesh)
    assert spec == P(("data",), None, "model")
    spec = SH.logical_to_spec(("vocab", "embed"), mesh, fsdp=True)
    assert spec == P("model", "data")
    spec = SH.logical_to_spec(("vocab", "embed"), mesh, fsdp=False)
    assert spec == P("model", None)


def test_fit_spec_drops_nondividing_axes():
    # pin a 1x1 mesh explicitly: with forced host devices (the CI 4-device
    # matrix) make_host_mesh() would be (4, 1) and 7 % 4 != 0 legitimately
    # drops 'data'
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    spec = SH._fit_spec_to_shape(P("data", "model"), (7, 8), mesh)
    # axis sizes are 1 here, so nothing is dropped
    assert spec == P("data", "model")
    # and on a mesh whose 'data' extent does NOT divide dim 0, it is dropped
    if len(jax.devices()) >= 2:
        mesh2 = Mesh(np.array(jax.devices()[:2]).reshape(2, 1),
                     ("data", "model"))
        assert SH._fit_spec_to_shape(
            P("data", "model"), (7, 8), mesh2) == P(None, "model")


def test_shard_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = SH.shard(x, "batch", None)
    assert (np.asarray(x) == np.asarray(y)).all()


def test_tree_shardings_with_shapes():
    mesh = make_host_mesh()
    logical = {"w": ("vocab", "embed"), "b": (None,)}
    shapes = {"w": jax.ShapeDtypeStruct((100, 8), jnp.float32),
              "b": jax.ShapeDtypeStruct((8,), jnp.float32)}
    sh = SH.tree_shardings(logical, mesh, shapes=shapes)
    assert sh["w"].spec == P("model", None)
    assert sh["b"].spec == P(None)


def test_use_mesh_context_restores():
    mesh = make_host_mesh()
    assert SH.current_mesh() is None
    with SH.use_mesh(mesh):
        assert SH.current_mesh() is mesh
        assert SH.axis_size("data") == mesh.shape["data"]
    assert SH.current_mesh() is None
    assert SH.axis_size("data") == 1


def test_sharded_forward_under_host_mesh():
    """Model forward runs unchanged under an active (degenerate) mesh."""
    from repro.configs import reduced_config
    from repro.models.lm import model as M

    cfg = reduced_config("llama3.2-1b")
    mesh = make_host_mesh()
    with SH.use_mesh(mesh):
        params, logical = M.init_params(cfg, jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
        logits, _ = M.forward_train(params, cfg, tokens)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
