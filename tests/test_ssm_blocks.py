"""SSD (Mamba-2) and RG-LRU: parallel forms vs sequential recurrences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models.lm import mamba2 as M2, rglru as RG

F32 = jnp.float32


def _naive_ssd(x, dtv, A, B, C):
    """Literal per-step recurrence h_t = exp(dt A) h_{t-1} + dt B x^T."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    st = np.zeros((b, h, n, p), np.float64)
    ys = np.zeros((b, s, h, p), np.float64)
    x, dtv, A, B, C = (np.asarray(v, np.float64) for v in (x, dtv, A, B, C))
    for t in range(s):
        dec = np.exp(dtv[:, t] * A[None, :])  # [b, h]
        upd = np.einsum("bn,bhp->bhnp", B[:, t], dtv[:, t][:, :, None] * x[:, t])
        st = st * dec[..., None, None] + upd
        ys[:, t] = np.einsum("bn,bhnp->bhp", C[:, t], st)
    return ys, st


@pytest.mark.parametrize("s,chunk", [(8, 4), (16, 4), (16, 8), (32, 16)])
def test_ssd_chunked_matches_naive_recurrence(s, chunk):
    rng = np.random.default_rng(0)
    b, h, p, n = 2, 3, 4, 5
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), F32)
    dtv = jnp.asarray(rng.uniform(0.01, 0.2, (b, s, h)), F32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), F32)
    B = jnp.asarray(rng.normal(size=(b, s, n)), F32)
    C = jnp.asarray(rng.normal(size=(b, s, n)), F32)
    y, final = M2.ssd_chunked(x, dtv, A, B, C, chunk)
    y_ref, final_ref = _naive_ssd(x, dtv, A, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), final_ref, rtol=2e-4,
                               atol=2e-4)


def test_ssd_step_continues_chunked_state():
    rng = np.random.default_rng(1)
    b, s, h, p, n = 1, 8, 2, 4, 3
    x = jnp.asarray(rng.normal(size=(b, s + 1, h, p)), F32)
    dtv = jnp.asarray(rng.uniform(0.01, 0.2, (b, s + 1, h)), F32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), F32)
    B = jnp.asarray(rng.normal(size=(b, s + 1, n)), F32)
    C = jnp.asarray(rng.normal(size=(b, s + 1, n)), F32)
    _, state = M2.ssd_chunked(x[:, :s], dtv[:, :s], A, B[:, :s], C[:, :s], 4)
    y_step, _ = M2.ssd_step(x[:, s:], dtv[:, s:], A, B[:, s:], C[:, s:], state)
    y_full, _ = M2.ssd_chunked(x, dtv, A, B, C, 4)
    np.testing.assert_allclose(np.asarray(y_step[:, 0]),
                               np.asarray(y_full[:, s]), rtol=2e-4, atol=2e-4)


def test_rglru_scan_matches_stepwise():
    cfg = reduced_config("recurrentgemma-2b")
    p, _ = RG.init_rglru_block(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.lru_width))
    a, b = RG._rglru_gates(p, x)
    # associative scan
    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br
    _, h_scan = jax.lax.associative_scan(comb, (a, b), axis=1)
    # sequential
    h = jnp.zeros((2, cfg.lru_width))
    hs = []
    for t in range(12):
        h = a[:, t] * h + b[:, t]
        hs.append(h)
    h_seq = jnp.stack(hs, 1)
    np.testing.assert_allclose(np.asarray(h_scan), np.asarray(h_seq),
                               rtol=1e-5, atol=1e-5)


def test_rglru_decay_in_unit_interval():
    """a_t = exp(-c softplus(L) r_t) must be in (0, 1] — stability invariant."""
    cfg = reduced_config("recurrentgemma-2b")
    p, _ = RG.init_rglru_block(jax.random.PRNGKey(0), cfg)
    x = 10.0 * jax.random.normal(jax.random.PRNGKey(2), (2, 6, cfg.lru_width))
    a, _ = RG._rglru_gates(p, x)
    assert float(a.min()) > 0.0 and float(a.max()) <= 1.0


def test_causal_conv1d_decode_matches_full():
    w = jax.random.normal(jax.random.PRNGKey(0), (4, 8))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, 8))
    y_full, _ = RG._causal_conv1d(x, w)
    # streaming: feed one step at a time with carried state
    state = jnp.zeros((2, 3, 8))
    outs = []
    for t in range(10):
        y, state = RG._causal_conv1d(x[:, t:t + 1], w, state)
        outs.append(y)
    y_stream = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_stream),
                               rtol=1e-5, atol=1e-5)
