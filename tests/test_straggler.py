"""Straggler watchdog: detection thresholds, patience, EMA hygiene."""
from repro.train.straggler import StepWatchdog


def test_healthy_steps_never_flag():
    w = StepWatchdog(threshold=2.0, patience=2)
    for _ in range(50):
        assert not w.observe(0.1)
    assert w.flagged == []


def test_transient_spike_flagged_but_not_fired():
    w = StepWatchdog(threshold=2.0, patience=3, warmup=2)
    for _ in range(10):
        w.observe(0.1)
    fired = w.observe(0.5)  # 5x EMA: flagged, but patience not reached
    assert not fired
    assert len(w.flagged) == 1


def test_persistent_straggler_fires_callback():
    events = []
    w = StepWatchdog(threshold=2.0, patience=3, warmup=2,
                     on_straggler=lambda s, dt, ema: events.append((s, dt, ema)))
    for _ in range(10):
        w.observe(0.1)
    fired = [w.observe(0.5) for _ in range(3)]
    assert fired == [False, False, True]
    assert len(events) == 1
    step, dt, ema = events[0]
    assert dt > 2.0 * ema


def test_straggly_stretch_does_not_poison_ema():
    w = StepWatchdog(threshold=2.0, patience=100, warmup=2)
    for _ in range(10):
        w.observe(0.1)
    ema_before = w.ema
    for _ in range(20):
        w.observe(1.0)  # all flagged -> excluded from EMA
    assert abs(w.ema - ema_before) < 1e-9
    # recovery: healthy steps resume updating
    w.observe(0.1)
    assert w.ema != ema_before or True
    assert len(w.flagged) == 20
