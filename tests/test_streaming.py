"""Streaming ring-buffer serving: bit-exactness, sessions, obs.

The contract under test is the whole point of `serve/stream.py`: every
window a `StreamEngine` answers — priming window and every incremental
step after it — is bit-identical to running `cu.run_qnet` on that window
in isolation, while computing only O(hop + halo) frames. The property
tests fuzz that equivalence across hop/window ratios, strides, kernels,
act widths and session interleavings; the rest covers the planner's
refusals, the session table (LRU eviction, lifecycle), and the
observability wiring.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import graph as G
from repro.models import dscnn1d
from repro.models.layers import make_calibrated_qnet
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer, validate_chrome_trace
from repro.serve import stream as ST

_QNETS = {}


def _qnet(**kw):
    """Tiny calibrated 1-D nets, memoized per geometry (quantize once)."""
    key = tuple(sorted(kw.items()))
    if key not in _QNETS:
        net = dscnn1d.build_kws(
            input_t=kw.get("input_t", 32), input_ch=4,
            channels=kw.get("channels", 8),
            n_blocks=kw.get("n_blocks", 2),
            kernel=kw.get("kernel", 3),
            stem_stride=kw.get("stem_stride", 2),
            bits=kw.get("bits", 8), num_classes=5,
            residual=kw.get("residual", False))
        _QNETS[key] = make_calibrated_qnet(net, seed=7)
    return _QNETS[key]


def _stream_all(eng, sid, frames, rng=None, chunk=None):
    """Push `frames` into `sid` in chunks; return stacked window logits."""
    out = []
    i = 0
    while i < len(frames):
        n = chunk or int(rng.integers(1, 9))
        out += eng.push(sid, frames[i:i + n])
        i += n
    return np.stack([r.logits for r in out]) if out else np.zeros((0,))


# ---------------------------------------------------------------------------
# planner geometry + refusals
# ---------------------------------------------------------------------------


def test_plan_halo_is_cheaper_than_full_window():
    qnet = _qnet(input_t=64, n_blocks=3)
    plan = ST.plan_stream(qnet, hop=8)
    assert 0 < plan.frames_step < plan.frames_full
    assert plan.reuse_fraction > 0.25
    assert plan.macs_step < plan.macs_full
    assert plan.buffer_bytes > 0


def test_plan_pointwise_passes_halo_through_unchanged():
    """PW layers must not grow the invalid region — that is the claim
    that makes the MAC-dominant layers O(hop + halo)."""
    qnet = _qnet(input_t=64, n_blocks=3)
    plan = ST.plan_stream(qnet, hop=8)
    for bs in plan.blocks:
        by_name = {os_.name: os_ for os_ in bs.ops}
        for os_ in bs.ops:
            if not os_.name.endswith("/pw"):
                continue
            dw = by_name.get(os_.name.replace("/pw", "/dw"))
            if dw is not None:
                assert (os_.lout, os_.rout) == (dw.lout, dw.rout)


def test_plan_refuses_2d_nets():
    from repro.models import mobilenet_v2 as mnv2
    net = mnv2.build(alpha=0.25, input_hw=32, bits=8, num_classes=4)
    qnet = make_calibrated_qnet(net, seed=0)
    with pytest.raises(ST.StreamError, match="1-D"):
        ST.plan_stream(qnet, hop=4)


def test_plan_refuses_hop_stride_mismatch():
    qnet = _qnet(stem_stride=2)
    with pytest.raises(ST.StreamError, match="stride"):
        ST.plan_stream(qnet, hop=3)  # stem stride 2 does not divide 3


def test_plan_refuses_bad_hop_range():
    qnet = _qnet()
    with pytest.raises(ST.StreamError, match="hop"):
        ST.plan_stream(qnet, hop=0)
    with pytest.raises(ST.StreamError, match="hop"):
        ST.plan_stream(qnet, hop=qnet.spec.input_hw + 2)


def test_plan_refuses_se_blocks():
    from repro.models import efficientnet as effn
    net = effn.build_compact(input_hw=32, bits=8, num_classes=4)
    qnet = make_calibrated_qnet(net, seed=0)
    with pytest.raises(ST.StreamError):
        ST.plan_stream(qnet, hop=4)


# ---------------------------------------------------------------------------
# bit-exactness vs the full-window reference
# ---------------------------------------------------------------------------


@settings(max_examples=8)
@given(seed=st.integers(0, 2**31 - 1),
       hop_div=st.sampled_from([2, 4, 8]),
       kernel=st.sampled_from([3, 5]),
       bits=st.sampled_from([4, 8]),
       stem_stride=st.sampled_from([1, 2]),
       residual=st.sampled_from([False, True]),
       fixed=st.sampled_from([False, True]))
def test_streaming_matches_full_window(seed, hop_div, kernel, bits,
                                       stem_stride, residual, fixed):
    """The property: for random geometry and a random chunking of the
    input stream, every streamed window's logits equal `run_qnet` on that
    window — both requant modes."""
    qnet = _qnet(input_t=32, kernel=kernel, bits=bits,
                 stem_stride=stem_stride, residual=residual)
    hop = qnet.spec.input_hw // hop_div
    rng = np.random.default_rng(seed)
    frames = rng.uniform(-1, 1, (ST.frames_for_windows(
        5, qnet.spec.input_hw, hop), qnet.spec.input_ch)).astype(np.float32)
    ref = ST.reference_windows(qnet, frames, qnet.spec.input_hw, hop,
                               fixed_point=fixed)
    eng = ST.StreamEngine(qnet, hop, fixed_point=fixed)
    sid = eng.open_session()
    got = _stream_all(eng, sid, frames, rng=rng)
    np.testing.assert_array_equal(got, ref)


@settings(max_examples=4)
@given(seed=st.integers(0, 2**31 - 1))
def test_interleaved_sessions_stay_isolated(seed):
    """Two sessions fed different streams in interleaved pushes each
    reproduce their own full-window reference — per-session ring buffers
    never bleed into each other."""
    qnet = _qnet(input_t=32, n_blocks=2)
    hop, window = 8, qnet.spec.input_hw
    rng = np.random.default_rng(seed)
    streams = {
        sid_tag: rng.uniform(-1, 1, (ST.frames_for_windows(4, window, hop),
                                     qnet.spec.input_ch)).astype(np.float32)
        for sid_tag in ("a", "b")
    }
    eng = ST.StreamEngine(qnet, hop)
    sids = {tag: eng.open_session(tag) for tag in streams}
    got = {tag: [] for tag in streams}
    pos = {tag: 0 for tag in streams}
    while any(pos[t] < len(streams[t]) for t in streams):
        tag = rng.choice(list(streams))
        if pos[tag] >= len(streams[tag]):
            continue
        n = int(rng.integers(1, 7))
        got[tag] += eng.push(sids[tag], streams[tag][pos[tag]:pos[tag] + n])
        pos[tag] += n
    for tag, frames in streams.items():
        ref = ST.reference_windows(qnet, frames, window, hop)
        np.testing.assert_array_equal(
            np.stack([r.logits for r in got[tag]]), ref)


def test_har_family_streams_bit_exact():
    """Strided DW blocks (HAR topology): halo through stride-2 layers."""
    net = dscnn1d.build_har(input_t=64, input_ch=3, stem_channels=6,
                            channels=[8, 12], kernel=5, bits=8,
                            num_classes=4)
    qnet = make_calibrated_qnet(net, seed=3)
    hop = 8  # cumulative stride 4 divides it
    rng = np.random.default_rng(11)
    frames = rng.uniform(-1, 1, (ST.frames_for_windows(4, 64, hop), 3)
                         ).astype(np.float32)
    ref = ST.reference_windows(qnet, frames, 64, hop)
    eng = ST.StreamEngine(qnet, hop)
    got = _stream_all(eng, eng.open_session(), frames, chunk=5)
    np.testing.assert_array_equal(got, ref)


def test_window_results_are_ordered_and_flagged():
    qnet = _qnet()
    hop = 8
    rng = np.random.default_rng(0)
    frames = rng.uniform(-1, 1, (ST.frames_for_windows(
        3, qnet.spec.input_hw, hop), qnet.spec.input_ch)).astype(np.float32)
    eng = ST.StreamEngine(qnet, hop)
    sid = eng.open_session()
    results = eng.push(sid, frames)
    assert [r.window for r in results] == [0, 1, 2]
    assert [r.streamed for r in results] == [False, True, True]


# ---------------------------------------------------------------------------
# batched stepping: drain / step_many
# ---------------------------------------------------------------------------


def _per_session(results):
    by = {}
    for r in results:
        by.setdefault(r.sid, []).append(r)
    for rs in by.values():
        assert [r.window for r in rs] == list(range(rs[0].window,
                                                    rs[0].window + len(rs)))
    return by


@settings(max_examples=4)
@given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([3, 5, 8, 9]))
def test_batched_drain_matches_serial_and_reference(seed, n):
    """The tentpole property: N sessions advanced through bucketed
    batched prime/step calls produce, per session, logits bit-identical
    to (a) the serial single-session path and (b) `cu.run_qnet` on every
    full window. n sweeps padding (3, 5), an exact bucket (8), and a
    max-chunk + straggler split (9)."""
    qnet = _qnet(input_t=32, n_blocks=2)
    hop, window = 8, qnet.spec.input_hw
    rng = np.random.default_rng(seed)
    streams = [rng.uniform(-1, 1, (ST.frames_for_windows(4, window, hop),
                                   qnet.spec.input_ch)).astype(np.float32)
               for _ in range(n)]
    serial = ST.StreamEngine(qnet, hop, max_sessions=n)
    got_serial = []
    for i in range(n):
        sid = serial.open_session()
        got_serial.append(np.stack(
            [r.logits for r in serial.push(sid, streams[i])]))
    batched = ST.StreamEngine(qnet, hop, max_sessions=n)
    sids = [batched.open_session() for _ in range(n)]
    for i, sid in enumerate(sids):
        assert batched.push(sid, streams[i], defer=True) == []
    by = _per_session(batched.drain())
    assert batched.stats()["windows_batched"] > 0  # really took the batch path
    for i, sid in enumerate(sids):
        got = np.stack([r.logits for r in by[sid]])
        ref = ST.reference_windows(qnet, streams[i], window, hop)
        np.testing.assert_array_equal(got, ref)
        np.testing.assert_array_equal(got, got_serial[i])


def test_drain_mixed_phase_groups():
    """One drain round can hold both a prime group and a step group: old
    sessions step while new ones prime, and a just-primed session steps
    in the next round — all bit-exact."""
    qnet = _qnet(input_t=32, n_blocks=2)
    hop, window = 8, qnet.spec.input_hw
    rng = np.random.default_rng(3)
    n_old, n_new = 3, 3
    frames = {f"old{i}": rng.uniform(-1, 1, (ST.frames_for_windows(
        3, window, hop), qnet.spec.input_ch)).astype(np.float32)
        for i in range(n_old)}
    frames.update({f"new{i}": rng.uniform(-1, 1, (ST.frames_for_windows(
        2, window, hop), qnet.spec.input_ch)).astype(np.float32)
        for i in range(n_new)})
    eng = ST.StreamEngine(qnet, hop)
    got = {sid: [] for sid in frames}
    for i in range(n_old):  # prime the old cohort first
        sid = f"old{i}"
        eng.open_session(sid)
        eng.push(sid, frames[sid][:window], defer=True)
    got_prime = _per_session(eng.drain())
    for sid, rs in got_prime.items():
        got[sid] += rs
    # now stage: old sessions hold 2 hops each (2 step rounds), new
    # sessions a full window + 1 hop (prime, then step)
    for i in range(n_old):
        eng.push(f"old{i}", frames[f"old{i}"][window:], defer=True)
    for i in range(n_new):
        sid = f"new{i}"
        eng.open_session(sid)
        eng.push(sid, frames[sid], defer=True)
    by = _per_session(eng.drain())
    for sid, rs in by.items():
        got[sid] += rs
    for sid, fr in frames.items():
        ref = ST.reference_windows(qnet, fr, window, hop)
        np.testing.assert_array_equal(
            np.stack([r.logits for r in got[sid]]), ref)


def test_step_many_advances_exactly_one_hop():
    qnet = _qnet()
    hop, window = 8, qnet.spec.input_hw
    rng = np.random.default_rng(1)
    eng = ST.StreamEngine(qnet, hop)
    sids = [eng.open_session() for _ in range(4)]
    streams = {}
    for sid in sids:
        streams[sid] = rng.uniform(-1, 1, (ST.frames_for_windows(
            3, window, hop), qnet.spec.input_ch)).astype(np.float32)
        eng.push(sid, streams[sid][:window])  # prime
        eng.push(sid, streams[sid][window:], defer=True)  # 2 hops staged
    r1 = eng.step_many(sids)
    assert sorted(r.window for r in r1) == [1] * 4  # ONE hop each
    r2 = eng.step_many(sids)
    assert sorted(r.window for r in r2) == [2] * 4
    assert eng.step_many(sids) == []  # pending dry: skipped, not an error
    for sid in sids:
        ref = ST.reference_windows(qnet, streams[sid], window, hop)
        got = np.stack([r.logits for r in r1 + r2 if r.sid == sid])
        np.testing.assert_array_equal(got, ref[1:])
    with pytest.raises(KeyError):
        eng.step_many(["nope"])


def test_eviction_between_stage_and_drain_drops_only_victim():
    """A session evicted after its frames were staged must vanish from
    the next drain without touching the survivors' results."""
    qnet = _qnet(input_t=32, n_blocks=2)
    hop, window = 8, qnet.spec.input_hw
    rng = np.random.default_rng(5)
    eng = ST.StreamEngine(qnet, hop, max_sessions=2)
    frames = {sid: rng.uniform(-1, 1, (ST.frames_for_windows(
        2, window, hop), qnet.spec.input_ch)).astype(np.float32)
        for sid in ("a", "b", "c")}
    for sid in ("a", "b"):
        eng.open_session(sid)
        eng.push(sid, frames[sid], defer=True)
    eng.open_session("c")  # evicts "a" (LRU) with its staged frames
    eng.push("c", frames["c"], defer=True)
    by = _per_session(eng.drain())
    assert set(by) == {"b", "c"}
    assert eng.stats()["sessions_evicted"] == 1.0
    for sid in ("b", "c"):
        ref = ST.reference_windows(qnet, frames[sid], window, hop)
        np.testing.assert_array_equal(
            np.stack([r.logits for r in by[sid]]), ref)


def test_batched_traces_bounded_by_buckets():
    """Retrace discipline: arbitrary fleet sizes may only ever trace one
    prime + one step program per bucket."""
    qnet = _qnet()
    hop, window = 8, qnet.spec.input_hw
    rng = np.random.default_rng(2)
    eng = ST.StreamEngine(qnet, hop, batch_buckets=(2, 4), max_sessions=16)
    for round_i, n in enumerate((2, 3, 5, 6, 4)):
        sids = [eng.open_session(f"r{round_i}_{i}") for i in range(n)]
        for sid in sids:
            eng.push(sid, rng.uniform(-1, 1, (window, qnet.spec.input_ch)
                                      ).astype(np.float32), defer=True)
        eng.drain()
    assert eng.stats()["batched_traces"] <= 2 * len(eng.batch_buckets)


def test_drain_without_buckets_falls_back_to_serial():
    qnet = _qnet()
    hop, window = 8, qnet.spec.input_hw
    rng = np.random.default_rng(9)
    eng = ST.StreamEngine(qnet, hop, batch_buckets=())
    frames = {eng.open_session(): rng.uniform(-1, 1, (window,
                                                      qnet.spec.input_ch)
                                              ).astype(np.float32)
              for _ in range(3)}
    for sid, fr in frames.items():
        eng.push(sid, fr, defer=True)
    by = _per_session(eng.drain())
    assert set(by) == set(frames)
    st = eng.stats()
    assert st["windows_batched"] == 0 and st["batched_calls"] == 0
    for sid, fr in frames.items():
        ref = ST.reference_windows(qnet, fr, window, hop)
        np.testing.assert_array_equal(
            np.stack([r.logits for r in by[sid]]), ref)


# ---------------------------------------------------------------------------
# session table
# ---------------------------------------------------------------------------


def test_lru_eviction_at_capacity():
    qnet = _qnet()
    eng = ST.StreamEngine(qnet, 8, max_sessions=2)
    a, b = eng.open_session("a"), eng.open_session("b")
    eng.push(a, np.zeros((1, qnet.spec.input_ch), np.float32))  # a now MRU
    eng.open_session("c")  # evicts b (LRU)
    assert eng.sessions_active == 2
    with pytest.raises(KeyError):
        eng.push(b, np.zeros((1, qnet.spec.input_ch), np.float32))
    assert eng.stats()["sessions_evicted"] == 1.0


def test_close_and_reopen_session():
    qnet = _qnet()
    eng = ST.StreamEngine(qnet, 8)
    sid = eng.open_session("s")
    assert eng.open_session("s") == sid  # reopen is a no-op
    assert eng.sessions_active == 1
    eng.close_session(sid)
    assert eng.sessions_active == 0
    with pytest.raises(KeyError):
        eng.close_session(sid)


def test_session_table_memory_counts_primed_sessions_only():
    qnet = _qnet()
    eng = ST.StreamEngine(qnet, 8)
    eng.open_session("cold")
    assert eng.session_table_bytes() == 0  # no buffers until primed
    sid = eng.open_session("hot")
    rng = np.random.default_rng(0)
    eng.push(sid, rng.uniform(-1, 1, (qnet.spec.input_hw,
                                      qnet.spec.input_ch)
                              ).astype(np.float32))
    assert eng.session_table_bytes() == eng.plan.buffer_bytes


def test_push_validates_inputs():
    qnet = _qnet()
    eng = ST.StreamEngine(qnet, 8)
    with pytest.raises(KeyError):
        eng.push("nope", np.zeros((1, qnet.spec.input_ch), np.float32))
    sid = eng.open_session()
    with pytest.raises(ValueError):
        eng.push(sid, np.zeros((1, qnet.spec.input_ch + 1), np.float32))


# ---------------------------------------------------------------------------
# session-lifecycle bugfix regressions
# ---------------------------------------------------------------------------


def test_auto_sid_skips_user_supplied_collisions():
    """Regression: the auto-sid counter must never hand out a sid a user
    already opened — that silently re-opened the foreign session (its
    buffers, its pending) instead of creating a fresh one."""
    qnet = _qnet()
    eng = ST.StreamEngine(qnet, 8)
    user = eng.open_session("s1")
    eng.push(user, np.zeros((3, qnet.spec.input_ch), np.float32),
             defer=True)
    assert eng.open_session() == "s0"
    fresh = eng.open_session()  # counter hits 1 -> "s1" taken -> skip
    assert fresh not in ("s0", "s1")
    assert eng.sessions_active == 3
    assert len(eng._sessions[fresh].pending) == 0  # NOT the user's state
    assert len(eng._sessions["s1"].pending) == 3  # user state untouched


def test_push_is_transactional_on_step_failure(monkeypatch):
    """Regression: a jitted step that raises (device OOM, bad buffer
    state) must not consume the staged frames — after recovery the same
    frames still produce the bit-exact window."""
    qnet = _qnet()
    hop, window = 8, qnet.spec.input_hw
    rng = np.random.default_rng(4)
    frames = rng.uniform(-1, 1, (ST.frames_for_windows(2, window, hop),
                                 qnet.spec.input_ch)).astype(np.float32)
    eng = ST.StreamEngine(qnet, hop)
    sid = eng.open_session()
    eng.push(sid, frames[:window])  # primed

    def boom(*a, **k):
        raise RuntimeError("device OOM")

    monkeypatch.setattr(eng, "_step", boom)
    with pytest.raises(RuntimeError, match="OOM"):
        eng.push(sid, frames[window:])
    assert len(eng._sessions[sid].pending) == hop  # frames NOT lost
    assert eng._sessions[sid].windows == 1  # no phantom window recorded
    monkeypatch.undo()
    res = eng.push(sid, np.zeros((0, qnet.spec.input_ch), np.float32))
    ref = ST.reference_windows(qnet, frames, window, hop)
    np.testing.assert_array_equal(
        np.stack([r.logits for r in res]), ref[1:])


def test_push_is_transactional_on_prime_failure(monkeypatch):
    qnet = _qnet()
    hop, window = 8, qnet.spec.input_hw
    rng = np.random.default_rng(6)
    frames = rng.uniform(-1, 1, (window, qnet.spec.input_ch)
                         ).astype(np.float32)
    eng = ST.StreamEngine(qnet, hop)
    sid = eng.open_session()

    def boom(*a, **k):
        raise RuntimeError("prime failed")

    monkeypatch.setattr(eng, "_prime", boom)
    with pytest.raises(RuntimeError, match="prime"):
        eng.push(sid, frames)
    sess = eng._sessions[sid]
    assert len(sess.pending) == window and sess.buffers is None
    monkeypatch.undo()
    res = eng.push(sid, np.zeros((0, qnet.spec.input_ch), np.float32))
    ref = ST.reference_windows(qnet, frames, window, hop)
    np.testing.assert_array_equal(
        np.stack([r.logits for r in res]), ref)


def test_reopen_refreshes_last_used():
    """Regression: re-opening an existing sid moved it in LRU order but
    left `last_used` stale — any recency policy reading the timestamp
    saw the session as idle."""
    qnet = _qnet()
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    eng = ST.StreamEngine(qnet, 8, clock=clock)
    eng.open_session("a")
    stale = eng._sessions["a"].last_used
    eng.open_session("b")
    eng.open_session("a")  # re-open
    assert eng._sessions["a"].last_used > stale
    assert next(reversed(eng._sessions)) == "a"  # MRU position too


def test_session_table_bytes_includes_pending_staging():
    """Regression: `session_table_bytes()` ignored the float32 pending
    staging arrays, under-reporting resident memory."""
    qnet = _qnet()
    hop, window, ch = 8, qnet.spec.input_hw, qnet.spec.input_ch
    rng = np.random.default_rng(8)
    eng = ST.StreamEngine(qnet, hop)
    sid = eng.open_session()
    eng.push(sid, rng.uniform(-1, 1, (hop, ch)).astype(np.float32),
             defer=True)  # staged, not yet primable
    pend = eng._sessions[sid].pending.nbytes
    assert pend == hop * ch * 4
    assert eng.session_table_buffer_bytes() == 0
    assert eng.session_table_pending_bytes() == pend
    assert eng.session_table_bytes() == pend
    # prime with 3 leftover frames: buffers + leftover staging both count
    eng.push(sid, rng.uniform(-1, 1, (window - hop + 3, ch)
                              ).astype(np.float32))
    stats = eng.stats()
    assert stats["session_table_buffer_bytes"] == eng.plan.buffer_bytes
    assert stats["session_table_pending_bytes"] == 3 * ch * 4
    assert stats["session_table_bytes"] == (eng.plan.buffer_bytes
                                            + 3 * ch * 4)


# ---------------------------------------------------------------------------
# observability
# ---------------------------------------------------------------------------


def test_stream_obs_counters_and_trace():
    """The satellite contract: sessions gauge, frames counters, lifecycle
    spans — and the exported trace passes the repo's own validator."""
    qnet = _qnet()
    hop = 8
    t = [0.0]

    def clock():
        t[0] += 0.001
        return t[0]

    tracer = Tracer(clock, origin_s=0.0)
    reg = MetricsRegistry()
    eng = ST.StreamEngine(qnet, hop, clock=clock, tracer=tracer,
                          metrics=reg, name="kws")
    rng = np.random.default_rng(0)
    frames = rng.uniform(-1, 1, (ST.frames_for_windows(
        3, qnet.spec.input_hw, hop), qnet.spec.input_ch)).astype(np.float32)
    sid = eng.open_session()
    eng.push(sid, frames)

    lbl = {"model": "kws"}
    active = reg.gauge("stream_sessions_active", labels=lbl)
    computed = reg.counter("stream_frames_computed_total", labels=lbl)
    reused = reg.counter("stream_frames_reused_total", labels=lbl)
    plan = eng.plan
    assert active.value == 1.0
    assert computed.value == plan.frames_full + 2 * plan.frames_step
    assert reused.value == 2 * (plan.frames_full - plan.frames_step)
    stats = eng.stats()
    assert stats["frames_computed_total"] == computed.value
    assert stats["frames_reused_total"] == reused.value

    eng.close_session(sid)
    assert active.value == 0.0

    doc = tracer.to_chrome()
    assert validate_chrome_trace(doc) == []
    names = {ev.get("name") for ev in doc["traceEvents"]}
    assert "stream_prime" in names and "stream_step" in names
    phases = [ev["ph"] for ev in doc["traceEvents"]
              if ev.get("name") == "stream_session:kws"]
    assert "b" in phases and "e" in phases  # lifecycle span opened+closed


def test_batched_obs_histogram_spans_and_pads():
    """Fleet-mode obs contract: `stream_batch_size` histogram records the
    REAL group size per dispatch, `stream_pad_rows_total` the bucket
    padding waste, and batched prime/step land as their own spans."""
    qnet = _qnet(input_t=32, n_blocks=2)
    hop, window = 8, qnet.spec.input_hw
    t = [0.0]

    def clock():
        t[0] += 0.001
        return t[0]

    tracer = Tracer(clock, origin_s=0.0)
    reg = MetricsRegistry()
    eng = ST.StreamEngine(qnet, hop, clock=clock, tracer=tracer,
                          metrics=reg, name="kws", batch_buckets=(4,))
    rng = np.random.default_rng(0)
    sids = [eng.open_session() for _ in range(3)]
    for sid in sids:  # window + 1 hop staged each
        eng.push(sid, rng.uniform(-1, 1, (window + hop, qnet.spec.input_ch)
                                  ).astype(np.float32), defer=True)
    eng.drain()  # round 1: prime batch of 3 (pad 1); round 2: step ditto

    lbl = {"model": "kws"}
    hist = reg.histogram("stream_batch_size", labels=lbl,
                         buckets=(1, 2, 4, 8, 16, 32, 64))
    assert hist.count == 2 and hist.sum == 6.0  # two dispatches of 3 real
    assert reg.counter("stream_pad_rows_total", labels=lbl).value == 2.0
    stats = eng.stats()
    assert stats["pad_rows"] == 2.0
    assert stats["windows_batched"] == 6.0
    assert stats["batched_calls"] == 2.0
    for sid in sids:
        eng.close_session(sid)
    doc = tracer.to_chrome()
    assert validate_chrome_trace(doc) == []
    names = {ev.get("name") for ev in doc["traceEvents"]}
    assert "stream_prime_batched" in names
    assert "stream_step_batched" in names
    # pad rows are physically computed: the frames counter sees 4-row
    # batches while reuse accounting credits only the 3 real sessions
    plan = eng.plan
    assert (reg.counter("stream_frames_computed_total", labels=lbl).value
            == 4 * plan.frames_full + 4 * plan.frames_step)
    assert (reg.counter("stream_frames_reused_total", labels=lbl).value
            == 3 * (plan.frames_full - plan.frames_step))


def test_eviction_closes_lifecycle_span():
    qnet = _qnet()
    tracer = Tracer(lambda: 1.0, origin_s=0.0)
    eng = ST.StreamEngine(qnet, 8, max_sessions=1, tracer=tracer)
    eng.open_session("a")
    eng.open_session("b")  # evicts a
    ends = [ev for ev in tracer.to_chrome()["traceEvents"]
            if ev["ph"] == "e" and ev.get("name", "").startswith(
                "stream_session")]
    assert len(ends) == 1


# ---------------------------------------------------------------------------
# tune-cache keys for 1-D shapes (satellite: rank-aware shape keys)
# ---------------------------------------------------------------------------


def test_op_key_rank_spelling_never_collides():
    from repro.tune.cache import op_key
    pw1 = G.OpSpec("x/pw", G.PW, 16, 32, 1, 1, G.RELU6, 8, 8)
    k1 = op_key(pw1, 12, "cpu", rank=1)
    k2 = op_key(pw1, 12, "cpu", rank=2)
    assert ":t12:" in k1 and ":hw12:" in k2 and k1 != k2
    dw1d = G.OpSpec("x/dw", G.DW1D, 16, 16, 3, 1, G.RELU6, 8, 8)
    assert ":t12:" in op_key(dw1d, 12, "cpu", rank=1)


def test_tuned_plan_round_trips_and_resolves_rank1(tmp_path):
    """Tune a tiny 1-D net with a fake timer, save/load the cache, and
    check a foreign-rank cache never matches: the 1-D entries resolve on
    the 1-D net, and the same entries spelled as 2-D resolve nothing."""
    from repro.tune import autotune as AT
    from repro.tune.cache import TunedPlan, load_tuned, save_tuned

    qnet = _qnet(input_t=32, n_blocks=2)
    tick = [0.0]

    def fake_measure(fn, x, candidate=None):
        tick[0] += 1.0
        return tick[0]  # deterministic: first verified candidate wins

    tuned = AT.tune_qnet(qnet, measure=fake_measure, include_pallas=False,
                         backend="cpu", verify_end_to_end=True)
    assert tuned.entries and all(":t" in k or ":t0:" in k
                                 for k in tuned.entries)
    assert not any(":hw" in k for k in tuned.entries)

    path = tmp_path / "tuned_1d.json"
    save_tuned(tuned, str(path))
    loaded = load_tuned(str(path))
    assert loaded.entries.keys() == tuned.entries.keys()
    routes, fused = loaded.resolve(qnet, backend="cpu")
    assert len(routes) == len(
        [op for _, op in qnet.spec.all_ops() if op.act != G.HSIGMOID])
    assert fused == set()
    assert loaded.coverage(qnet, backend="cpu") == 1.0

    # the same numbers spelled as 2-D keys must resolve NOTHING on rank 1
    foreign = TunedPlan(
        backend="cpu", nets=("x",), tuned_batch=1,
        entries={k.replace(":t", ":hw", 1): v
                 for k, v in tuned.entries.items()})
    routes_f, _ = foreign.resolve(qnet, backend="cpu")
    assert routes_f == {}


def test_tuned_rank1_plan_runs_bit_exact_through_prepare():
    """A resolved 1-D plan attached via `prepare_qnet(tuned=...)` keeps
    logits identical to the untuned reference."""
    import jax.numpy as jnp

    from repro.core import cu
    from repro.tune import autotune as AT

    qnet = _qnet(input_t=32, n_blocks=2)
    tuned = AT.tune_qnet(qnet, measure=lambda fn, x, c=None: 1.0,
                         include_pallas=False, backend="cpu",
                         verify_end_to_end=False)
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.uniform(-1, 1, (2, *qnet.spec.input_shape())
                                ).astype(np.float32))
    ref = np.asarray(cu.run_qnet(qnet, x))
    pq = cu.prepare_qnet(qnet, tuned=tuned)
    assert pq.routes  # the plan actually attached
    np.testing.assert_array_equal(np.asarray(cu.run_qnet(pq, x)), ref)


# ---------------------------------------------------------------------------
# registry (satellite: dscnn archs are first-class, self-describing)
# ---------------------------------------------------------------------------


def test_registry_builds_and_round_trips_dscnn(tmp_path):
    from repro.configs.registry import (DSCNN_ARCHS, get_netspec,
                                        netspec_build_record)
    from repro.core.qnet import load_qnet, save_qnet

    for arch in DSCNN_ARCHS:
        spec = get_netspec(arch)
        assert spec.spatial_rank == 1
        assert spec.num_classes == 12

    # shrunken knobs ride through the build record -> artifact -> reload
    kw = dict(input_t=32, input_ch=4, channels=8, n_blocks=1, num_classes=3)
    spec = get_netspec("dscnn_kws", **kw)
    qnet = make_calibrated_qnet(spec, seed=0)
    path = str(tmp_path / "kws.qnet")
    save_qnet(qnet, path, build=netspec_build_record("dscnn_kws", **kw))
    loaded = load_qnet(path)  # no NetSpec in hand: self-describing
    assert loaded.spec.name == spec.name
    rng = np.random.default_rng(2)
    from repro.core import cu
    x = rng.uniform(-1, 1, (2, *spec.input_shape())).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(cu.run_qnet(loaded, x)),
                                  np.asarray(cu.run_qnet(qnet, x)))


def test_registry_rejects_unknown_arch():
    from repro.configs.registry import netspec_build_record
    with pytest.raises(KeyError, match="dscnn"):
        netspec_build_record("dscnn_nope")
