"""Training substrate: optimizer, grad accumulation, compression, convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.data.pipeline import DataConfig, lm_batch
from repro.models.lm import model as M
from repro.train import grad_compress as GC, optimizer as O
from repro.train.train_loop import make_train_step


def test_lr_schedule_shapes():
    cfg = O.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(O.lr_at(cfg, jnp.int32(s))) for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.0, abs=1e-6)


def test_adamw_reduces_quadratic_loss():
    params = {"w": jnp.asarray([5.0, -3.0])}
    ocfg = O.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                         total_steps=200, schedule="constant")
    st = O.init_state(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, st, _ = O.apply_updates(params, g, st, ocfg)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_grad_accumulation_equivalence():
    """grad_accum=2 must equal grad_accum=1 on the same global batch."""
    cfg = reduced_config("llama3.2-1b")
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    ocfg = O.AdamWConfig(lr=1e-3, total_steps=10, warmup_steps=0)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                          cfg.vocab)}
    p1, _, m1 = make_train_step(cfg, ocfg, grad_accum=1)(
        params, O.init_state(params), batch)
    p2, _, m2 = make_train_step(cfg, ocfg, grad_accum=2)(
        params, O.init_state(params), batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        # summation-order differences flip the last bf16 bit on a handful of
        # params; allow 2 ULP at the parameter scale (~0.25)
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=4e-3)


def test_training_reduces_loss_on_structured_stream():
    """E2E: a tiny LM learns the synthetic next-token structure."""
    cfg = reduced_config("llama3.2-1b")
    data = DataConfig(seed=7, vocab=cfg.vocab, seq_len=32, global_batch=8)
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    ocfg = O.AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=80)
    step = jax.jit(make_train_step(cfg, ocfg), donate_argnums=(0, 1))
    opt = O.init_state(params)
    losses = []
    for s in range(80):
        batch = {k: jnp.asarray(v) for k, v in lm_batch(data, s).items()}
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 1.0, losses[::10]


def test_gradient_compression_error_feedback():
    """Error feedback keeps long-run compressed-grad average unbiased."""
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
    err = GC.init_error(g_true)
    acc = jnp.zeros((64,))
    n = 200
    for _ in range(n):
        comp, err = GC.compress_tree(g_true, err)
        acc = acc + comp["w"]
    drift = float(jnp.abs(acc / n - g_true["w"]).max())
    assert drift < 0.02, drift


def test_compressed_training_still_converges():
    cfg = reduced_config("llama3.2-1b")
    data = DataConfig(seed=7, vocab=cfg.vocab, seq_len=32, global_batch=8)
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    ocfg = O.AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=60)
    step = jax.jit(make_train_step(cfg, ocfg, compress=True),
                   donate_argnums=(0, 1))
    opt = O.init_state(params)
    err = GC.init_error(params)
    losses = []
    for s in range(60):
        batch = {k: jnp.asarray(v) for k, v in lm_batch(data, s).items()}
        params, opt, err, metrics = step(params, opt, batch, err)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.7


def test_data_pipeline_determinism_and_sharding():
    base = DataConfig(seed=3, vocab=100, seq_len=16, global_batch=8)
    b1 = lm_batch(base, 5)
    b2 = lm_batch(base, 5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # different steps differ
    assert not np.array_equal(b1["tokens"], lm_batch(base, 6)["tokens"])
    # host sharding partitions the global batch
    h0 = lm_batch(DataConfig(seed=3, vocab=100, seq_len=16, global_batch=8,
                             n_hosts=2, host_id=0), 5)
    assert h0["tokens"].shape[0] == 4
