"""E2E FPGA-aware QAT vision pipeline: train -> online-quantize -> export
-> serve (paper Fig. 1), proven by bitwise export conformance.

Three invariants this tier pins:

  * **QAT smoke**: a tiny MobileNetV2 trains through the full phase
    schedule (float+BN -> BN fusion -> QAT with act-bit anneal) and
    reduces loss; microbatched grad accumulation included.
  * **Restart continuation**: checkpoint -> kill -> resume reproduces the
    straight run's parameters bitwise — including when the kill lands
    before the BN-fusion boundary (the tree changes shape across it).
  * **Export conformance**: the artifact a *trained* net freezes is
    bit-exact across the reference interpreter, `prepare_qnet`, the jitted
    stage executors, and a tuned `VisionEngine` — and the `.qnet` written
    to disk reloads (build record alone) into the same logits.
"""
import dataclasses
import os

import jax
import numpy as np
import pytest

from repro.core import cu, qnet as Q
from repro.serve.vision import VisionEngine
from repro.train import vision as V
from repro.tune import tune_qnet

CFG = V.VisionTrainConfig(
    model="mobilenet_v2", alpha=0.35, input_hw=16, num_classes=4,
    float_steps=4, qat_steps=4, batch=8, anneal_from=8,
    calibrate_every=2, ckpt_every=2,
)


def _fake_measure(times=()):
    times = dict(times)

    def measure(fn, x, candidate=None):
        return times.get(candidate.route if candidate else None, 1.0)

    return measure


def _leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.fixture(scope="module")
def straight():
    """One uninterrupted run of the full schedule (no checkpoints)."""
    return V.train(dataclasses.replace(CFG, ckpt_every=0))


@pytest.fixture(scope="module")
def restarted(tmp_path_factory):
    """Killed-and-resumed runs, one per kill point: before the BN-fusion
    boundary (the tree changes shape across it) and mid-annealed-QAT.
    Shared module-wide — training compiles are the expensive part."""
    runs = {}
    for kill_at in (3, 7):
        ckpt = str(tmp_path_factory.mktemp(f"ck{kill_at}"))
        part = V.train(CFG, ckpt_dir=ckpt, stop_after=kill_at)
        assert part.step == kill_at and not part.done
        runs[kill_at] = V.train(CFG, ckpt_dir=ckpt, resume=True)
    return runs


# ---------------------------------------------------------------------------
# QAT smoke
# ---------------------------------------------------------------------------


def test_phase_schedule_partitions_steps():
    phases = V.phase_schedule(CFG)
    assert [p.name for p in phases] == ["float", "qat_act8", "qat_act4"]
    assert phases[0].start == 0 and phases[-1].stop == CFG.total_steps
    for a, b in zip(phases, phases[1:]):
        assert a.stop == b.start
    # anneal: first QAT phase at 8-bit activations, final at the target BW
    assert phases[1].act_bits == 8 and phases[2].act_bits == CFG.act_bits
    for step in range(CFG.total_steps):
        ph = phases[V.phase_at(CFG, step)]
        assert ph.start <= step < ph.stop


def test_qat_smoke_trains_and_fuses_bn(straight):
    assert straight.done and straight.step == CFG.total_steps
    losses = straight.history["loss"]
    assert len(losses) == CFG.total_steps
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0], losses
    # BN fused away at the float -> QAT boundary
    assert not any("bn" in p for p in straight.params.values())
    # online quantization ran every `calibrate_every` QAT steps and
    # re-derived the ReLU6-fused quantizer at the phase's bit-width
    rounds = straight.history["calibration"]
    assert [r["act_bits"] for r in rounds] == [8, 4]
    for r in rounds:
        assert r["relu6_scale"] == pytest.approx(6.0 / (2 ** r["act_bits"] - 1))
        assert r["relu6_zp"] == 0.0
    # the rounds left every observer with a finite tracked range (the
    # state the export consumes); a fresh observer set is NOT ready
    assert V.observers_ready(straight.observers)
    assert not V.observers_ready(V.init_observers(CFG))
    assert set(straight.observers) == set(V.observer_keys(straight.net))


def test_build_net_honors_act_bits_distinct_from_weight_bits():
    """A config deploying at a different activation BW than its weight BW
    (bits=4, act_bits=8) must train/quantize THAT spec — and the build
    record must rebuild it from the artifact alone."""
    cfg = dataclasses.replace(CFG, bits=4, act_bits=8)
    net = V.build_net(cfg)
    ops = [op for b in net.blocks for op in b.ops]
    assert all(op.act_bits == 8 for op in ops)
    assert all(op.bits in (4, 8) for op in ops)  # weight BW untouched
    assert Q.build_netspec(V.build_record(cfg)) == net
    # anneal override reaches a third width
    net6 = V.build_net(cfg, act_bits=6)
    assert all(op.act_bits == 6 for b in net6.blocks for op in b.ops)
    # the default equal-width config is unchanged by the record round-trip
    assert Q.build_netspec(V.build_record(CFG)) == V.build_net(CFG)


def test_stop_after_requires_ckpt_dir():
    """A preemption point without a checkpoint directory would silently
    discard the run — train() must refuse it up front."""
    with pytest.raises(ValueError, match="ckpt_dir"):
        V.train(CFG, stop_after=3)


def test_bn_running_stats_move_during_float_phase():
    cfg = dataclasses.replace(CFG, float_steps=1, qat_steps=0, ckpt_every=0)
    res = V.train(cfg)
    moved = [name for name, p in res.params.items()
             if "bn" in p and float(np.abs(np.asarray(p["bn"]["mean"])).max()) > 0]
    assert moved, "no BN running mean moved off init"


def test_grad_accum_microbatching_runs():
    """Microbatched grad accumulation (lax.scan with the BN-moment aux
    threaded through) produces finite losses on the QAT step."""
    cfg = dataclasses.replace(CFG, float_steps=2, qat_steps=0, grad_accum=2,
                              ckpt_every=0, calibrate_every=0)
    res = V.train(cfg)
    assert res.done and np.isfinite(res.history["loss"]).all()
    assert any("bn" in p for p in res.params.values())


# ---------------------------------------------------------------------------
# checkpoint -> kill -> resume, bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kill_at", [3, 7],
                         ids=["mid-float-pre-fusion", "mid-qat"])
def test_checkpoint_restart_bitwise_continuation(straight, restarted, kill_at):
    """Straight N steps == (k steps + checkpoint + kill + resume) bitwise.

    kill_at=3 lands before the BN-fusion boundary (the resumed process
    must rebuild the *unfused* template, then fuse at the boundary
    itself); kill_at=7 lands strictly inside the final annealed QAT phase
    — the restored mid-phase AdamW state (fused tree shape) must continue
    the straight run's stream, and so must the checkpointed
    online-quantization observers."""
    resumed = restarted[kill_at]
    assert resumed.done and resumed.step == CFG.total_steps
    _leaves_equal(straight.params, resumed.params)
    # the run log rides the checkpoint manifest: a resumed run reports the
    # WHOLE run (loss curve, calibration rounds), not the post-resume tail
    assert resumed.history["loss"] == straight.history["loss"]
    assert (len(resumed.history["calibration"])
            == len(straight.history["calibration"]))
    assert set(straight.observers) == set(resumed.observers)
    _leaves_equal(
        {k: [o.min_val, o.max_val] for k, o in straight.observers.items()},
        {k: [o.min_val, o.max_val] for k, o in resumed.observers.items()})


def test_export_deterministic_after_restart(straight, restarted):
    """The artifact is a pure function of the run state: exporting from a
    resumed run — with its restored online-quantization observers —
    freezes byte-identical integer constants."""
    resumed = restarted[7]
    assert V.observers_ready(straight.observers)
    assert V.observers_ready(resumed.observers)
    qa, _ = V.export(straight.params, straight.net, CFG, verify=False,
                     observers=straight.observers)
    qb, _ = V.export(resumed.params, resumed.net, CFG, verify=False,
                     observers=resumed.observers)
    for name in qa.ops:
        np.testing.assert_array_equal(qa.ops[name].w_q, qb.ops[name].w_q)
        np.testing.assert_array_equal(qa.ops[name].mantissa,
                                      qb.ops[name].mantissa)
        np.testing.assert_array_equal(qa.ops[name].bias_q, qb.ops[name].bias_q)
    assert qa.res_q == qb.res_q


# ---------------------------------------------------------------------------
# export -> serve conformance (the acceptance gate)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def exported(straight, tmp_path_factory):
    path = str(tmp_path_factory.mktemp("export") / "trained.qnet")
    tuned = tune_qnet(
        Q.quantize_net(straight.params, straight.net,
                       _export_observers(straight)),
        batch=4, measure=_fake_measure(), include_pallas=False)
    qnet, report = V.export(straight.params, straight.net, CFG, path=path,
                            tuned=tuned)
    return qnet, report, path, tuned


def _export_observers(straight):
    """From-scratch export calibration — the same single recipe export()
    itself runs when no trained observers are handed in."""
    return V.run_calibration(straight.params, straight.net, CFG,
                             momentum=None)[0]


def test_export_parity_all_routes(exported):
    """Reference / prepared / stage executors / tuned VisionEngine: one
    trained artifact, four serving routes, zero LSB drift."""
    _, report, _, tuned = exported
    assert report["verified"]
    routes = report["routes"]
    assert "reference" in routes
    assert "prepared" in routes
    assert "stage-executors" in routes
    assert "engine[tuned]" in routes  # the tuned plan really attached
    assert report["tuned_entries"] == len(tuned) > 0


def test_exported_artifact_reloads_and_serves(exported):
    """Disk -> build record -> NetSpec -> VisionEngine, bit-exact with the
    pre-freeze verification logits."""
    qnet, report, path, _ = exported
    assert os.path.getsize(path) > 0
    x = np.asarray(V.calibration_batches(CFG)[0])
    # route 1: core loader, no NetSpec in hand
    reloaded = Q.load_qnet(path)
    np.testing.assert_array_equal(
        np.asarray(cu.run_qnet(reloaded, x)), report["logits"])
    # route 2: the serve-side artifact loader
    eng = VisionEngine.from_artifact(path, buckets=(x.shape[0],))
    rids = [eng.submit(img) for img in x]
    res = eng.run()
    got = np.stack([res[r].logits for r in rids])
    np.testing.assert_array_equal(got, report["logits"])


def test_exported_artifact_schema(exported):
    _, _, path, _ = exported
    meta = Q.read_qnet_meta(path)
    assert meta["build"]["model"] == "mobilenet_v2"
    assert meta["build"]["input_hw"] == CFG.input_hw
    prov = meta["provenance"]
    for key in ("total_steps", "float_steps", "qat_steps", "act_bits",
                "seed", "data_seed", "calib_seed", "verified_routes"):
        assert key in prov, key
    assert prov["verified_routes"], "artifact frozen without a parity proof"
    for name, m in meta["ops"].items():
        assert {"in_scale", "in_zp", "out_scale", "out_zp", "clip",
                "bits"} <= set(m), name


def test_verify_export_catches_drift(exported):
    """The parity gate actually fires: corrupt one requant constant and the
    export proof must refuse the artifact."""
    qnet, report, _, _ = exported
    broken = Q.QNet(qnet.spec,
                    {k: dataclasses.replace(v) for k, v in qnet.ops.items()},
                    dict(qnet.res_q))
    name = next(iter(broken.ops))
    qop = broken.ops[name]
    broken.ops[name] = dataclasses.replace(qop, mult=np.asarray(qop.mult) * 1.5)
    x = np.asarray(V.calibration_batches(CFG)[0])
    cus, acts, logits = V.stage_vectors(qnet, x)  # reference = intact net
    with pytest.raises(V.ExportParityError):
        got = np.asarray(cu.run_qnet(broken, x))
        V._check_equal("corrupted", got, logits, [])


def test_launch_driver_check_artifact(exported, capsys):
    from repro.launch.train_vision import check_artifact
    _, _, path, _ = exported
    assert check_artifact(path) == 0
    out = capsys.readouterr().out
    assert "routes bit-exact" in out
