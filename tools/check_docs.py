"""Docs gate: relative-link resolution + architecture.md package coverage.

Run from anywhere inside the repo:

    python tools/check_docs.py

Checks, over README.md and every docs/*.md:

  1. every relative markdown link target resolves to a real file or
     directory (links with a URL scheme are skipped; so are targets that
     escape the repo root, like the README CI badge's GitHub-relative
     ../../actions/... path — they are not filesystem claims),
  2. every ``#fragment`` pointing at a markdown file matches a heading in
     that file (GitHub anchor slug rules),
  3. docs/architecture.md references every package under src/repro/ —
     a new package cannot land without a line in the architecture map.

Exit status 0 on success, 1 with one line per problem otherwise. Wired
into CI as the ``docs`` job and into tier-1 via tests/test_docs.py.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
SCHEME_RE = re.compile(r"^[a-zA-Z][a-zA-Z0-9+.-]*:")


def repo_root() -> Path:
    return Path(__file__).resolve().parent.parent


def doc_files(root: Path) -> list[Path]:
    return [root / "README.md", *sorted((root / "docs").glob("*.md"))]


def heading_slugs(md_text: str) -> set[str]:
    """GitHub-style anchor slugs for every heading in a markdown text."""
    slugs = set()
    for m in HEADING_RE.finditer(md_text):
        title = m.group(1).strip().replace("`", "")
        slug = re.sub(r"[^\w\- ]", "", title).strip().lower().replace(" ", "-")
        slugs.add(slug)
    return slugs


def check_links(doc: Path, root: Path) -> list[str]:
    errors = []
    text = doc.read_text()
    rel = doc.relative_to(root)
    for target in LINK_RE.findall(text):
        if SCHEME_RE.match(target):
            continue  # external URL
        path_part, _, fragment = target.partition("#")
        if path_part:
            resolved = (doc.parent / path_part).resolve()
            if not resolved.is_relative_to(root):
                continue  # GitHub-relative (badge/actions), not a file claim
            if not resolved.exists():
                errors.append(f"{rel}: broken link -> {target}")
                continue
        else:
            resolved = doc  # pure in-page anchor
        if fragment and resolved.suffix == ".md":
            if fragment.lower() not in heading_slugs(resolved.read_text()):
                errors.append(f"{rel}: dangling anchor -> {target}")
    return errors


def check_architecture_coverage(root: Path) -> list[str]:
    arch = root / "docs" / "architecture.md"
    if not arch.exists():
        return ["docs/architecture.md is missing"]
    text = arch.read_text()
    errors = []
    for pkg in sorted(p for p in (root / "src" / "repro").iterdir()
                      if p.is_dir() and (p / "__init__.py").exists()):
        if f"src/repro/{pkg.name}/" not in text:
            errors.append(
                f"docs/architecture.md: package src/repro/{pkg.name}/ is "
                "not referenced in the architecture map"
            )
    return errors


def collect_errors(root: Path | None = None) -> list[str]:
    root = (root or repo_root()).resolve()
    errors = []
    for doc in doc_files(root):
        if not doc.exists():
            errors.append(f"{doc.relative_to(root)} is missing")
            continue
        errors.extend(check_links(doc, root))
    errors.extend(check_architecture_coverage(root))
    return errors


def main() -> int:
    errors = collect_errors()
    for err in errors:
        print(err, file=sys.stderr)
    if errors:
        print(f"check_docs: {len(errors)} problem(s)", file=sys.stderr)
        return 1
    n = len(doc_files(repo_root()))
    print(f"check_docs: {n} files OK (links resolve, architecture map covers src/repro/*)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
